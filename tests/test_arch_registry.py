"""Architecture registry: heterogeneous cache disciplines, one Arena.

Acceptance centerpiece: a scripted mixed workload serving a transformer
(growing paged KV), a pure SSM (constant state) and a zamba2 hybrid
(both) CONCURRENTLY from one shared Arena, token-identical per family
to standalone runs, with forced preemption/resume cycles hitting all
three pool-class disciplines -- including a constant-state block round-
tripping through the host tier -- and ``assert_quiescent`` clean at
drain.

Satellites pinned here: registry resolution (family -> strategy -> pool
classes, unservable rows loud), EDF admission ordering and its exact
degradation to the pre-EDF FIFO, per-tenant block quotas rejecting
over-quota admissions, the read-only segment's share/refuse-write
contract, and a property test interleaving alloc/free across two pool
classes of one arena.
"""

import numpy as np
import pytest
import jax

from repro.configs.base import get_config
from repro.mem import Arena
from repro.models.api import build_model
from repro.serve.arch import (ARCHITECTURES, CompositeStrategy,
                              ConstantStateStrategy, PagedKVStrategy,
                              ReadOnlySegment, build_strategy, resolve)
from repro.serve.engine import Engine, Request
from repro.serve.scheduler import FCFSAdmission
from conftest import assert_engine_quiescent
from _hypothesis_compat import given, settings, strategies as st


@pytest.fixture(scope="module")
def families():
    """One tiny model per discipline: paged / constant / composite."""
    out = {}
    for key, name in (("dense", "gemma_2b"), ("ssm", "mamba2_370m"),
                      ("hybrid", "zamba2_2p7b")):
        cfg = get_config(name).reduced()
        model = build_model(cfg)
        params, _ = model.init(jax.random.PRNGKey(hash(key) % 2**31))
        out[key] = (model, params)
    return out


def _prompts(rng, n, lo=6, hi=20):
    return [rng.randint(2, 500, size=rng.randint(lo, hi)) for _ in range(n)]


def _make_engine(model, params, *, arena, prefix, num_blocks):
    return Engine(model, params, slots=2, max_seq=64,
                  num_blocks=num_blocks, eos_id=-1, prefill_budget=None,
                  arena=arena, pool_prefix=prefix)


# ---------------------------------------------------------------------------
# registry resolution
# ---------------------------------------------------------------------------
def test_resolve_maps_families_to_disciplines(families):
    assert resolve(families["dense"][0]).strategy is PagedKVStrategy
    assert resolve(families["ssm"][0]).strategy is ConstantStateStrategy
    assert resolve(families["hybrid"][0]).strategy is CompositeStrategy


def test_registered_but_unservable_rows_raise():
    """whisper (read-only cross-attention segment) is REGISTERED -- the
    table documents the discipline -- but building it for serving is
    loudly refused.  rwkv6 graduated to served once its padded prefill
    learned to mask lengths."""
    rows = {r.key: r for r in ARCHITECTURES}
    assert not rows["audio"].served
    assert rows["rwkv6"].served
    model = build_model(get_config("whisper_tiny").reduced())
    with pytest.raises(NotImplementedError):
        build_strategy(model, arena=Arena(), slots=2, max_seq=64,
                       num_blocks=16)


def test_engine_pool_classes_match_registry(families):
    """The engine's strategy registers exactly the registry row's pool
    classes (prefix-namespaced) and the constant class never grows."""
    arena = Arena()
    eng = _make_engine(*families["hybrid"], arena=arena, prefix="zb-",
                       num_blocks=24)
    assert eng.strategy.pool_classes == ["zb-kv", "zb-state"]
    assert eng.strategy.growing_classes == frozenset(["zb-kv"])
    assert not eng.share_prefixes and not eng.suffix_prefill
    eng.release_arena()


# ---------------------------------------------------------------------------
# THE acceptance test: three disciplines, one Arena
# ---------------------------------------------------------------------------
def _drive(engines, *, preempt_at=(), max_steps=200):
    """Round-robin step loop over engines sharing one arena; at each
    step index in ``preempt_at``, force-preempt every engine's LIFO
    victim (swap-out -> host tier -> later resume)."""
    for step in range(max_steps):
        if all(not (e.sched.has_work or e.running) for e in engines):
            break
        if step in preempt_at:
            for e in engines:
                e.preempt_latest()
        for e in engines:
            e.step()
    for e in engines:
        e.transfers.drain()
    return {id(e): {r.rid: list(r.generated) for r in e.done}
            for e in engines}


def test_mixed_disciplines_share_one_arena(families):
    """transformer + mamba2 + zamba2 served concurrently from ONE
    Arena: per-family tokens identical to each engine running alone in
    a private arena, despite forced preemption/resume through all three
    disciplines' pool classes mid-run."""
    rng = np.random.RandomState(11)
    prompts = {k: _prompts(np.random.RandomState(100 + i), 3)
               for i, k in enumerate(("dense", "ssm", "hybrid"))}

    # standalone references: private arena, no preemption
    ref = {}
    for key in prompts:
        model, params = families[key]
        eng = _make_engine(model, params, arena=None, prefix="",
                           num_blocks=24)
        for i, pr in enumerate(prompts[key]):
            eng.submit(Request(rid=i, prompt=pr, max_new=5))
        eng.run(200)
        ref[key] = {r.rid: list(r.generated) for r in eng.done}
        assert_engine_quiescent(eng)

    # mixed: one arena, prefix-namespaced pool classes, forced churn
    arena = Arena()
    engines = {}
    for key, prefix in (("dense", ""), ("ssm", "m2-"), ("hybrid", "zb-")):
        model, params = families[key]
        engines[key] = _make_engine(model, params, arena=arena,
                                    prefix=prefix, num_blocks=24)
        for i, pr in enumerate(prompts[key]):
            engines[key].submit(Request(rid=i, prompt=pr, max_new=5))

    # warm up until everything admitted at least once, then preempt
    for _ in range(3):
        for e in engines.values():
            e.step()
    for e in engines.values():
        e.preempt_latest()
    # the preemption swap-out reached every discipline's pool class:
    # the SSM engine's victim moved its CONSTANT-STATE block to the
    # host tier, the hybrid's victim moved kv AND state
    for e in engines.values():
        e.sync_transfers()
    assert len(engines["ssm"].mgr.swapped) >= 1
    assert len(engines["ssm"].store) >= 1          # state payload on host
    assert len(engines["hybrid"].mgr.swapped) >= 1
    assert len(engines["hybrid"].strategy.state_mgr.swapped) >= 1
    assert len(engines["dense"].mgr.swapped) >= 1

    _drive(list(engines.values()), preempt_at=(2,), max_steps=200)

    for key, eng in engines.items():
        assert eng.preemptions >= 1
        got = {r.rid: list(r.generated) for r in eng.done}
        assert got == ref[key], f"family {key} diverged under sharing"
        assert eng.stats["swap_outs"] >= 1 and eng.stats["swap_ins"] >= 1

    # per-pool-class accounting is visible in the shared ArenaStats
    stats = arena.stats()
    for cls in ("kv", "m2-state", "zb-kv", "zb-state"):
        assert cls in stats.classes
        assert stats[cls].num_used == stats[cls].pinned  # only sinks left

    # one address space, fully quiescent at drain
    for eng in engines.values():
        assert_engine_quiescent(eng)
    arena.assert_quiescent()


def test_constant_state_preempt_resume_is_exact(families):
    """One SSM sequence, preempted mid-generation: the resumed run's
    tokens equal the uninterrupted run's -- the whole recurrent state
    rode ONE host block round-trip."""
    model, params = families["ssm"]
    rng = np.random.RandomState(5)
    pr = rng.randint(2, 500, size=9)

    eng = _make_engine(model, params, arena=None, prefix="", num_blocks=4)
    eng.submit(Request(rid=0, prompt=pr, max_new=8))
    eng.run(100)
    ref = list(eng.done[0].generated)
    assert_engine_quiescent(eng)

    eng = _make_engine(model, params, arena=None, prefix="", num_blocks=4)
    eng.submit(Request(rid=0, prompt=pr, max_new=8))
    for _ in range(3):
        eng.step()
    eng.preempt_latest()
    eng.sync_transfers()
    assert eng.mgr.swapped == {0: 1}       # exactly one block moved
    assert 0 in eng.store
    eng.run(100)
    assert list(eng.done[0].generated) == ref
    assert eng.stats["swap_ins"] == 1
    assert_engine_quiescent(eng)


# ---------------------------------------------------------------------------
# EDF admission (satellite)
# ---------------------------------------------------------------------------
def _req(rid, *, pc=0, deadline=None):
    return Request(rid=rid, prompt=np.asarray([2, 3]), max_new=2,
                   priority_class=pc, deadline=deadline)


def test_edf_orders_within_priority_class():
    pol = FCFSAdmission()
    for r in (_req(0, deadline=50.0), _req(1, deadline=10.0),
              _req(2),                      # best effort -> +inf, last
              _req(3, pc=-1, deadline=99.0),  # higher class wins anyway
              _req(4, deadline=10.0)):        # ties break on submission
        pol.push(r)
    assert [pol.pop().rid for _ in range(5)] == [3, 1, 4, 0, 2]


def test_edf_degrades_exactly_to_fifo_without_deadlines():
    """All-best-effort queues sort (class, +inf, index): EXACTLY the
    pre-EDF priority-bucketed FIFO -- pinned so the default workload's
    schedule is bit-identical across the EDF change."""
    pol = FCFSAdmission()
    for r in (_req(0, pc=1), _req(1), _req(2, pc=1), _req(3)):
        pol.push(r)
    assert [pol.pop().rid for _ in range(4)] == [1, 3, 0, 2]


# ---------------------------------------------------------------------------
# per-tenant quotas (satellite)
# ---------------------------------------------------------------------------
def test_over_quota_tenant_is_rejected_not_stalled(families):
    model, params = families["dense"]
    eng = Engine(model, params, slots=4, max_seq=64, num_blocks=32,
                 eos_id=-1, prefill_budget=None, share_prefixes=False)
    cfg = eng.cache.config
    # idempotent re-registration updates the quota on the live class
    eng.arena.register_class("kv", num_blocks=cfg.num_blocks,
                             block_nbytes=cfg.swap_nbytes_per_block(),
                             dp_groups=cfg.dp_groups,
                             quota_by_tenant={"capped": 2})
    rng = np.random.RandomState(3)
    pr = rng.randint(2, 500, size=14)
    # worst case 14 + 10 = 24 tokens = 3 blocks > the 2-block quota
    eng.submit(Request(rid=0, prompt=pr, max_new=10, tenant="capped"))
    eng.submit(Request(rid=1, prompt=pr, max_new=4))
    done = eng.run(100)
    by_rid = {r.rid: r for r in done}
    assert by_rid[0].state == "rejected" and not by_rid[0].generated
    assert by_rid[1].state == "done" and len(by_rid[1].generated) == 4
    assert eng.rejections == 1 and eng.stats["rejections"] == 1
    # the quota and the per-tenant charge surface in ArenaStats
    assert eng.arena.stats()["kv"].quota_by_tenant == {"capped": 2}
    assert_engine_quiescent(eng)


def test_within_quota_tenant_is_admitted(families):
    model, params = families["ssm"]
    eng = Engine(model, params, slots=2, max_seq=64, num_blocks=4,
                 eos_id=-1, prefill_budget=None)
    eng.arena.register_class("state", num_blocks=4,
                             block_shape=(model.state_elems,),
                             dtype=np.float32,
                             quota_by_tenant={"t": 1})
    pr = np.random.RandomState(4).randint(2, 500, size=8)
    eng.submit(Request(rid=0, prompt=pr, max_new=3, tenant="t"))
    done = eng.run(50)
    assert done[0].state == "done" and len(done[0].generated) == 3
    assert eng.rejections == 0
    assert_engine_quiescent(eng)


# ---------------------------------------------------------------------------
# read-only segment (whisper's cross-attention discipline)
# ---------------------------------------------------------------------------
def test_readonly_segment_shares_and_refuses_writes():
    a = Arena()
    a.register_class("xattn", num_blocks=8, block_nbytes=64)
    seg = ReadOnlySegment(a, "xattn")
    ids = seg.deposit("enc", 3)            # encode writes once
    assert len(ids) == 3
    for beam in ("b0", "b1", "b2"):
        assert seg.share("enc", beam) == ids   # pure aliasing
    alloc = a.allocator("xattn")
    for b in ids:
        assert alloc.refcount(b) == 4      # segment + 3 beams, 0 copies
    with pytest.raises(TypeError):
        seg.ensure_writable("enc", 0)      # read-only IS the contract
    for beam in ("b0", "b1", "b2"):
        seg.drop_reader(beam)
    for b in ids:
        assert alloc.refcount(b) == 1
    seg.release("enc")
    a.assert_quiescent()


# ---------------------------------------------------------------------------
# property: alloc/free interleaved across two pool classes
# ---------------------------------------------------------------------------
@given(st.lists(st.sampled_from(
    ["grow-kv", "admit-state", "free-kv", "free-state"]), max_size=60))
@settings(max_examples=40, deadline=None)
def test_two_pool_classes_never_interfere(ops):
    """Any interleaving of growth in a paged-style class and admit/
    release in a constant-style class keeps both allocators' accounting
    exact and independent -- one arena, two disciplines, no bleed."""
    a = Arena()
    a.register_class("kv", num_blocks=12, block_nbytes=32)
    a.register_class("state", num_blocks=5, block_nbytes=64)
    kv = a.mapping("kv", "seq")
    states = {}
    next_sid, kv_blocks = 0, 0
    for op in ops:
        if op == "grow-kv" and kv_blocks < 12:
            kv.append_blocks(1)
            kv_blocks += 1
        elif op == "admit-state" and len(states) < 5:
            m = a.mapping("state", f"s{next_sid}")
            m.ensure_capacity(1)
            states[next_sid] = m
            next_sid += 1
        elif op == "free-kv" and kv_blocks:
            kv.pop_block()
            kv_blocks -= 1
        elif op == "free-state" and states:
            sid, m = next(iter(states.items()))
            m.free()
            del states[sid]
        assert a.num_used("kv") == kv_blocks
        assert a.num_used("state") == len(states)
        assert a.num_free("kv") == 12 - kv_blocks
        assert a.num_free("state") == 5 - len(states)
    kv.free()
    for m in states.values():
        m.free()
    a.assert_quiescent()
