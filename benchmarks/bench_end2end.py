"""Paper Fig. 5: end-to-end overhead of the block/tree discipline on a
real workload -- here, serving decode with a PAGED KV cache vs a
CONTIGUOUS KV cache (the virtual-memory-style preallocated rectangle),
on the reduced gemma-2b.

Also reports the paper's §3 claim that performance is insensitive to
block size (bench_blocksize section)."""

from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.configs.base import get_config
from repro.core.paged_kv import PagedKVCache, PagedKVManager
from repro.models.api import build_model, make_concrete_batch


def _contiguous_decode_step(model, cfg, max_seq):
    """Baseline: dense (B, S_max, KVH, hd) cache per layer, no tables."""

    def step(p, tokens, k_cache, v_cache, lens):
        # emulate via a paged cache with identity tables and bt = max_seq
        B = tokens.shape[0]
        kvcfg = model.kv_config(max_seq=max_seq, num_blocks=B, batch=B)
        kvcfg = dataclasses.replace(kvcfg, block_tokens=max_seq,
                                    num_blocks=B, max_blocks_per_seq=1)
        cache = PagedKVCache(k_cache, v_cache,
                             jnp.arange(B, dtype=jnp.int32)[:, None],
                             lens, kvcfg)
        logits, cache = model.decode_step(p, tokens, cache)
        return logits, cache.k_pool, cache.v_pool, cache.seq_lens

    return step


def run() -> None:
    cfg = get_config("gemma_2b").reduced()
    model = build_model(cfg)
    p, _ = model.init(jax.random.PRNGKey(0))
    B, max_seq = 8, 256
    rng = np.random.RandomState(0)
    tokens = jnp.asarray(rng.randint(0, cfg.vocab_size, B))

    for bt in (8, 16, 32, 64):
        kvcfg = dataclasses.replace(
            model.kv_config(max_seq=max_seq, batch=B), block_tokens=bt,
            num_blocks=B * max_seq // bt, max_blocks_per_seq=max_seq // bt)
        cache = PagedKVCache.create(kvcfg, B)
        mgr = PagedKVManager(kvcfg)
        tb = []
        for s in range(B):
            mgr.admit(s, max_seq)
            tb.append(mgr.device_table(s))
        cache = dataclasses.replace(
            cache, block_tables=jnp.asarray(np.stack(tb)),
            seq_lens=jnp.full((B,), max_seq // 2, jnp.int32))
        f = jax.jit(lambda pp, tt, cc: model.decode_step(pp, tt, cc))
        us = time_fn(f, p, tokens, cache)
        emit(f"decode_paged_bt{bt}", us, f"B={B},ctx={max_seq // 2}")

    # contiguous baseline
    L, KVH, hd = cfg.num_layers, cfg.kv_heads, cfg.hd
    k_cache = jnp.zeros((L, B, max_seq, KVH, hd), jnp.float32)
    v_cache = jnp.zeros_like(k_cache)
    lens = jnp.full((B,), max_seq // 2, jnp.int32)
    step = _contiguous_decode_step(model, cfg, max_seq)
    f = jax.jit(step)
    us = time_fn(f, p, tokens, k_cache, v_cache, lens)
    emit("decode_contiguous", us, f"B={B},ctx={max_seq // 2}")


if __name__ == "__main__":
    run()
