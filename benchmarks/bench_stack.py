"""Paper Fig. 3: split-stack overhead.

gcc's split stack adds a ~3-instruction space check per function call;
the paper measures ~2% typical, 15% on a pathological call-bound
microbenchmark (recursive fib).  Our BlockStack is the same mechanism as
a runtime structure: push() performs the check-and-maybe-link.  We
measure (a) the pathological case -- recursive fib carrying its frames
on a BlockStack vs a plain Python list (contiguous, amortized-growth);
(b) a 'typical' workload -- the serving scheduler's admission loop,
where stack ops are a small fraction of the work.
"""

from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core.stack import BlockStack


def _fib_with_stack(n: int, stack) -> int:
    """Iterative fib with an explicit call stack (pathological: every
    'call' is a push/pop pair)."""
    stack.push((n, 0, 0))
    result = 0
    while len(stack):
        m, phase, acc = stack.pop()
        if m <= 1:
            result = m
            continue
        if phase == 0:
            stack.push((m, 1, 0))
            stack.push((m - 1, 0, 0))
        elif phase == 1:
            stack.push((m, 2, result))
            stack.push((m - 2, 0, 0))
        else:
            result = acc + result
    return result


class ListStack:
    """Contiguous baseline (amortized doubling, like a normal stack)."""

    __slots__ = ("_l",)

    def __init__(self):
        self._l = []

    def push(self, x):
        self._l.append(x)

    def pop(self):
        return self._l.pop()

    def __len__(self):
        return len(self._l)


def _time(fn, iters=5):
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def run() -> None:
    N = 22
    us_list = _time(lambda: _fib_with_stack(N, ListStack()))
    us_block = _time(lambda: _fib_with_stack(N, BlockStack(block_size=4096)))
    emit("fib_stack_contiguous", us_list, "")
    emit("fib_stack_split", us_block,
         f"overhead={(us_block / us_list - 1) * 100:.1f}%")

    # typical: admission bookkeeping where stack ops are ~5% of work
    def typical(stack_cls):
        s = stack_cls() if stack_cls is ListStack else \
            BlockStack(block_size=4096)
        acc = 0.0
        for i in range(20000):
            s.push(i)
            for _ in range(12):           # 'real work'
                acc += i * 1e-9
            if i % 3 == 0 and len(s):
                s.pop()
        return acc

    us_list = _time(lambda: typical(ListStack))
    us_block = _time(lambda: typical(BlockStack))
    emit("typical_stack_contiguous", us_list, "")
    emit("typical_stack_split", us_block,
         f"overhead={(us_block / us_list - 1) * 100:.1f}%")


if __name__ == "__main__":
    run()
