"""Serving-stack benchmark: throughput, swap traffic, prefix-share rate.

    PYTHONPATH=src python benchmarks/bench_serve.py --smoke

Drives the layered engine through a scripted workload (mixed prompts, a
shared-prefix cohort, and a pool small enough to force preemption) and
writes ``BENCH_serve.json``:

  * tokens_per_s        -- decoded tokens / wall time
  * swap_bytes_per_step -- (swap_out + swap_in bytes) / engine steps
  * swap_bytes_per_block / blocks_swapped -- proportionality evidence:
    per-block swap cost must equal config.swap_nbytes_per_block()
  * prefix_share_hit_rate -- forked admissions / total requests
  * prefetch_hit_rate   -- resumes served from a COMPLETED speculative
    swap-in / total swap-ins (the multi-queue plane's background h2d
    lane); ``--smoke`` additionally runs ``prefetch_probe`` -- a
    scripted forced-preemption workload shaped so the LIFO victim's
    resume stays blocked on its worst-case footprint while its current
    blocks fit -- and CI gates ``prefetch_hits > 0`` on it
  * cow_copies, preemptions, compactions, pool_utilization_final
  * arena                -- the unified address space's ``ArenaStats``
    snapshot (blocks by owner/placement per pool class, refcount
    histogram, fragmentation, table locality)
  * transfers           -- the transfer plane's ``TransferStats``
    (plans/bytes/queue depth/overlap per ENGINE, coalesced and
    reorder-window launches, prefetch-lane counters); also written
    standalone to ``BENCH_transfers.json`` together with the
    per-engine queue depths and both modes' throughput

``--smoke`` additionally re-runs the identical workload with
``overlap_transfers=False`` -- the single-queue synchronous ``drain()``
fallback (one serialized schedule, prefetch off) -- and asserts the
multi-queue+prefetch schedule decodes PER-REQUEST-TOKEN- and
demand-swap-byte-identical outputs: the per-engine queues and the
speculation may only reschedule traffic, never change what any request
decodes or how many bytes its swaps move (speculative blocks are
credited as free at admission and cancelled first under pressure).
The equivalence pins deliberately compare per-request tokens, never
step counts, so the wall-clock-adaptive prefill budget (the
``"auto"`` default) stays out of the pinned surface; the scripted
workload itself runs with ``prefill_budget=None`` (deterministic).

``--trace poisson`` (the ``--smoke`` default) additionally drives a
fresh engine through ``Engine.serve`` over a seeded, replayable
arrival trace -- requests ARRIVE on the engine's step clock instead of
pre-loading the batch -- and records per-tenant p50/p99 TTFT and
inter-token latency (``tenant_latency``), the TTFT histogram
(``latency_histogram``) and the trace parameters (``arrival_trace``)
in BENCH_serve.json.

``--smoke`` also runs ``suffix_probe``: the ``prefixheavy`` arrival
trace served twice -- suffix-only prefill for forked children (the
default) vs full recompute (``suffix_prefill=False``) -- and CI gates
per-request token identity between the modes plus
``prefill_tokens_saved > 0`` on the suffix run; both modes' tokens/s
land in BENCH_transfers.json under ``modes``.

``--smoke`` also runs ``decode_path_probe``: the scripted workload
served with the device-resident decode path (persistent block tables,
delta sync, one fused buffer-donated decode step -- the default) vs
the eager full-rebuild fallback (``resident_tables=False``), gated on
per-request token identity and on the resident path performing
strictly fewer host uploads per step than eager's fixed two; each
mode's tokens/s, phase breakdown and table-sync traffic land under
``decode_path``.

``--smoke`` also runs ``mixed_arch_probe``: transformer + mamba2 +
zamba2 served concurrently from ONE shared Arena through the
architecture registry (``serve/arch.py``), gated on per-family token
identity vs standalone runs, a forced preemption round-trip through
every pool-class discipline, and arena quiescence at drain; per-family
tokens/s and per-pool-class block stats land under ``mixed_arch``.

``--smoke`` also runs ``migrate_probe``: the scripted workload migrated
MID-DECODE to a fresh engine (incremental pre-copy + stop-and-copy via
``serve/disagg.migrate_live``), gated on token identity with an
unmigrated control and a stop-and-copy tail strictly smaller than the
first pre-copy round, plus a prefill/decode disaggregation run gated
token-identical to the monolithic engine; the section lands standalone
in ``BENCH_migrate.json``.

``--baseline PATH`` compares tokens/s against a committed report and
exits non-zero on a regression beyond ``--regress-frac`` (CI gate).
Emits the usual CSV rows too (see benchmarks/common.py).
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np
import jax

OUT_JSON = "BENCH_serve.json"
OUT_TRANSFERS = "BENCH_transfers.json"
OUT_MIGRATE = "BENCH_migrate.json"


# model/params reused between the overlapped and drain() runs of
# --smoke (identical weights are a precondition of the equivalence
# assertion); lives for the process like any loaded checkpoint would
_MODEL_CACHE = {}


def build(args, overlap: bool = True):
    from repro.configs.base import get_config
    from repro.models.api import build_model
    from repro.serve.engine import Engine

    key = (args.arch, bool(args.reduced), args.max_seq, args.seed)
    if key not in _MODEL_CACHE:
        cfg = get_config(args.arch)
        if args.reduced:
            cfg = cfg.reduced()
        model = build_model(cfg, max_positions=args.max_seq)
        params, _ = model.init(jax.random.PRNGKey(args.seed))
        _MODEL_CACHE[key] = (cfg, model, params)
    cfg, model, params = _MODEL_CACHE[key]
    eng = Engine(model, params, slots=args.slots, max_seq=args.max_seq,
                 num_blocks=args.num_blocks, eos_id=-1,
                 watermark=args.watermark,
                 prefill_budget=args.prefill_budget,
                 overlap_transfers=overlap,
                 resident_tables=getattr(args, "resident_tables", True))
    return cfg, eng


def drive(cfg, eng, args):
    """Run the scripted workload; returns wall time.  Forces at least
    one preemption round-trip mid-run so swap traffic is always
    measured, even when the pool happens to fit everything."""
    workload(cfg, eng, args)
    forced = False
    t0 = time.perf_counter()
    while (eng.sched.has_work or eng.running) and eng.steps < 10_000:
        eng.step()
        if eng.steps == 4 and eng.running and not forced:
            eng.preempt_latest()
            forced = True
    eng.sync_transfers()
    return time.perf_counter() - t0


def trace_probe(args):
    """Live-traffic section: a seeded arrival trace through
    ``Engine.serve`` (continuous batching -- admit/retire every step,
    never drain the batch) with the adaptive ``"auto"`` prefill budget,
    reporting per-tenant latency percentiles.  Replayable: the same
    seed produces the same arrivals and token-identical decodes; only
    the wall-clock latencies vary run to run."""
    import argparse as _ap
    from repro.serve.traffic import make_trace

    pargs = _ap.Namespace(**{**vars(args), "prefill_budget": "auto"})
    cfg, eng = build(pargs)
    source = make_trace(args.trace, args.requests, cfg.vocab_size,
                        seed=args.seed, mean_gap=args.trace_gap,
                        tenants=args.trace_tenants, max_new=args.max_new,
                        prompt_cap=min(24, args.max_seq // 2),
                        shared_frac=0.25)
    n = len(source)
    t0 = time.perf_counter()
    eng.serve(source, max_steps=100_000)
    dt = time.perf_counter() - t0
    eng.sync_transfers()
    st = eng.stats
    ttfts = [(r.t_first - r.t_submit) * 1e3 for r in eng.done
             if r.t_first >= 0 and r.t_submit >= 0]
    counts, edges = np.histogram(ttfts, bins=8) if ttfts else \
        (np.zeros(8, int), np.zeros(9))
    return {
        "arrival_trace": {"kind": args.trace, "seed": args.seed,
                          "requests": n, "tenants": args.trace_tenants,
                          "mean_gap_steps": args.trace_gap},
        "completed": len(eng.done),
        "steps": eng.steps,
        "tokens_per_s": round(st["decode_tokens"] / max(dt, 1e-9), 2),
        "preemptions": st["preemptions"],
        "prefix_hits": st["prefix_hits"],
        "tenant_latency": eng.latency_report(),
        "latency_histogram": {"metric": "ttft_ms",
                              "edges_ms": [round(float(e), 3)
                                           for e in edges],
                              "counts": [int(c) for c in counts]},
    }


def prefetch_probe(args):
    """Scripted forced-preemption workload whose LIFO resume is served
    from a COMPLETED speculative prefetch (the CI hit-rate gate).

    Shape: two long growers fill two slots, a short filler's completion
    admits a YOUNG victim mid-flight, and the forced eviction at step
    34 lands in the window where the victim's worst-case footprint is
    blocked (free - wc < watermark) while its current blocks fit
    (free - cur >= watermark) -- so the background h2d scatter runs and
    completes during the multi-step wait, and the eventual resume
    commits it (see serve/README.md's step-loop timeline).  Everything
    is deterministic: greedy decode, fixed lengths, eos never fires.
    """
    import argparse as _ap
    from repro.serve.engine import Engine, Request

    pargs = _ap.Namespace(**{**vars(args), "slots": 3, "max_seq": 64,
                             "num_blocks": 20, "watermark": 2})
    cfg, eng = build(pargs)
    rng = np.random.RandomState(args.seed)
    for rid, (plen, max_new) in enumerate(
            ((8, 48), (8, 48), (8, 8), (8, 40))):
        eng.submit(Request(rid=rid,
                           prompt=rng.randint(2, cfg.vocab_size, size=plen),
                           max_new=max_new))
    forced = False
    while (eng.sched.has_work or eng.running) and eng.steps < 400:
        eng.step()
        if eng.steps == 34 and eng.running and not forced:
            eng.preempt_latest()
            forced = True
    eng.sync_transfers()
    st = eng.stats
    return {
        "completed": len(eng.done),
        "steps": eng.steps,
        "preemptions": st["preemptions"],
        "prefetches": st["prefetches"],
        "prefetch_hits": st["prefetch_hits"],
        "prefetch_cancels": st["prefetch_cancels"],
        "prefetch_hit_rate": round(st["prefetch_hit_rate"], 3),
        "queue_depths": st["transfers"]["max_pending"],
        "overlapped": st["transfers"]["overlapped"],
    }


def suffix_probe(args):
    """Prefix-heavy arrival trace served twice -- suffix-only prefill
    (default) vs full recompute (``suffix_prefill=False``) -- pinning
    per-request token identity between the modes and recording the
    prefill work the suffix path skipped (``prefill_tokens_saved``)."""
    import argparse as _ap
    from repro.serve.traffic import make_trace

    # deterministic budget: the wall-clock-adaptive "auto" schedule
    # would admit the two modes differently (the suffix mode's cheaper
    # billing is the one scheduling difference we WANT to measure)
    pargs = _ap.Namespace(**{**vars(args), "prefill_budget": None})
    runs: dict = {"suffix": [], "full-recompute": []}
    gen, stats_by, done_by = {}, {}, {}
    # order-balanced best-of-2: the first run of either mode pays any
    # residual jit tracing and the second run of a pair is always
    # warmer, so alternate and take each mode's best
    for mode in ("suffix", "full-recompute", "full-recompute", "suffix"):
        cfg, eng = build(pargs)
        eng.suffix_prefill = (mode == "suffix") and eng.suffix_prefill
        source = make_trace("prefixheavy", args.requests,
                            cfg.vocab_size, seed=args.seed,
                            mean_gap=args.trace_gap,
                            tenants=args.trace_tenants,
                            max_new=args.max_new,
                            prompt_cap=min(24, args.max_seq // 2))
        t0 = time.perf_counter()
        eng.serve(source, max_steps=100_000)
        runs[mode].append(time.perf_counter() - t0)
        eng.sync_transfers()
        stats_by[mode] = eng.stats
        gen[mode] = {r.rid: list(r.generated) for r in eng.done}
        done_by[mode] = len(eng.done)
    out = {}
    for mode, dts in runs.items():
        st = stats_by[mode]
        out[mode] = {
            "tokens_per_s": round(
                st["decode_tokens"] / max(min(dts), 1e-9), 2),
            "prefill_tokens": st["prefill_tokens"],
            "prefill_tokens_saved": st["prefill_tokens_saved"],
            "prefix_hits": st["prefix_hits"],
            "completed": done_by[mode],
        }
    out["token_identical"] = gen["suffix"] == gen["full-recompute"]
    return out


def decode_path_probe(args):
    """Resident-decode section: the scripted forced-preemption workload
    served twice -- device-resident tables + delta sync + the fused
    donated decode tail (``resident_tables=True``, the default) vs the
    eager full-rebuild fallback (``False``) -- pinning per-request
    token identity between the paths and gating the whole point of the
    refactor: the resident path must perform strictly fewer host
    uploads per step than the eager path's fixed two (full table sync +
    token vector).  Order-balanced best-of-2 per mode; each mode's
    tokens/s, per-step phase breakdown and table-sync traffic land in
    BENCH_serve.json under ``decode_path``.
    """
    import argparse as _ap

    pargs = _ap.Namespace(**{**vars(args), "prefill_budget": None})
    runs: dict = {"resident": [], "eager": []}
    gen, stats_by, done_by = {}, {}, {}
    for mode in ("resident", "eager", "eager", "resident"):
        margs = _ap.Namespace(**{**vars(pargs),
                                 "resident_tables": mode == "resident"})
        cfg, eng = build(margs)
        runs[mode].append(drive(cfg, eng, margs))
        stats_by[mode] = eng.stats
        gen[mode] = {r.rid: list(r.generated) for r in eng.done}
        done_by[mode] = len(eng.done)
    out = {}
    for mode, dts in runs.items():
        st = stats_by[mode]
        out[mode] = {
            "tokens_per_s": round(
                st["decode_tokens"] / max(min(dts), 1e-9), 2),
            "completed": done_by[mode],
            "host_uploads": st["host_uploads"],
            "host_uploads_per_step": round(st["host_uploads_per_step"], 3),
            "table_sync_bytes": st["table_sync_bytes"],
            "table_rows_updated": st["table_rows_updated"],
            "phase_time_s": {k: round(v, 4)
                             for k, v in st["phase_time_s"].items()},
        }
    out["token_identical"] = gen["resident"] == gen["eager"]
    out["ok"] = (out["token_identical"]
                 and done_by["resident"] == args.requests
                 and done_by["eager"] == args.requests
                 and out["resident"]["host_uploads_per_step"]
                 < out["eager"]["host_uploads_per_step"])
    return out


def mixed_arch_probe(args):
    """Architecture-registry section: a transformer (growing paged KV),
    a pure SSM (constant state) and a zamba2 hybrid (both) served
    CONCURRENTLY from ONE shared Arena -- pool classes prefix-
    namespaced per engine -- with a forced preemption round-trip
    through every discipline.  CI gates per-family token identity
    against each engine's standalone (private-arena, unpreempted) run
    and a clean ``assert_quiescent`` at drain; per-family tokens/s and
    the shared arena's per-pool-class block stats land in
    BENCH_serve.json under ``mixed_arch``.
    """
    from repro.configs.base import get_config
    from repro.mem import Arena
    from repro.models.api import build_model
    from repro.serve.engine import Engine, Request

    fams = (("dense", "gemma_2b", ""), ("ssm", "mamba2_370m", "m2-"),
            ("hybrid", "zamba2_2p7b", "zb-"))
    models = {}
    for fam, name, prefix in fams:
        key = ("mixed_arch", name, args.seed)
        if key not in _MODEL_CACHE:
            cfg = get_config(name).reduced()
            model = build_model(cfg)
            params, _ = model.init(jax.random.PRNGKey(args.seed))
            _MODEL_CACHE[key] = (cfg, model, params)
        models[fam] = (prefix,) + _MODEL_CACHE[key]

    def make(fam, arena):
        prefix, cfg, model, params = models[fam]
        return Engine(model, params, slots=2, max_seq=64, num_blocks=24,
                      eos_id=-1, prefill_budget=None, arena=arena,
                      pool_prefix=prefix if arena is not None else "")

    rng = np.random.RandomState(args.seed)
    prompts = {fam: [rng.randint(2, 500, size=int(rng.randint(6, 20)))
                     for _ in range(3)] for fam, _, _ in fams}

    def submit(eng, fam):
        for i, pr in enumerate(prompts[fam]):
            eng.submit(Request(rid=i, prompt=pr, max_new=4))

    # standalone references: private arena, no preemption
    ref = {}
    for fam, _, _ in fams:
        eng = make(fam, None)
        submit(eng, fam)
        eng.run(400)
        ref[fam] = {r.rid: list(r.generated) for r in eng.done}

    arena = Arena()
    engines = {fam: make(fam, arena) for fam, _, _ in fams}
    for fam, eng in engines.items():
        submit(eng, fam)
    steps, forced = 0, False
    t0 = time.perf_counter()
    while (any(e.sched.has_work or e.running for e in engines.values())
           and steps < 400):
        for e in engines.values():
            e.step()
        steps += 1
        if steps == 3 and not forced:
            # one forced eviction per engine: the dense victim moves
            # paged KV, the SSM victim ONE constant-state block, the
            # hybrid victim both classes in one dispatch
            for e in engines.values():
                e.preempt_latest()
            forced = True
    dt = time.perf_counter() - t0
    for e in engines.values():
        e.sync_transfers()

    ok = forced
    families = {}
    for fam, eng in engines.items():
        st = eng.stats
        got = {r.rid: list(r.generated) for r in eng.done}
        match = got == ref[fam]
        ok = (ok and match and st["preemptions"] >= 1
              and st["swap_ins"] >= 1)
        families[fam] = {
            "strategy": type(eng.strategy).__name__,
            "pool_classes": list(eng.strategy.pool_classes),
            "completed": len(eng.done),
            "decode_tokens": st["decode_tokens"],
            "tokens_per_s": round(st["decode_tokens"] / max(dt, 1e-9), 2),
            "preemptions": st["preemptions"],
            "swap_outs": st["swap_outs"],
            "swap_ins": st["swap_ins"],
            "tokens_match": match,
        }
    astats = arena.stats()
    per_class = {name: {"num_blocks": c.num_blocks,
                        "num_used": c.num_used, "num_free": c.num_free,
                        "pinned": c.pinned, "host_blocks": c.host_blocks}
                 for name, c in sorted(astats.classes.items())}
    try:
        arena.assert_quiescent()
        quiescent = True
    except AssertionError:
        quiescent = ok = False
    return {"families": families, "per_class_blocks": per_class,
            "steps": steps, "wall_s": round(dt, 3),
            "arena_quiescent": quiescent, "ok": ok}


def migrate_probe(args):
    """Cross-process section: (1) a serving engine migrated MID-DECODE
    -- pre-copy rounds overlapping decode, dirty-set convergence, a
    stop-and-copy tail strictly smaller than the first round's full
    copy -- must resume on a fresh engine token-identical to an
    unmigrated control, across a forced preemption; (2) prefill/decode
    disaggregation (prefill worker -> BlockBundle handoff -> decode
    adoption) must be token-identical to the monolithic engine.  The
    whole section lands in ``BENCH_migrate.json`` and gates ``all_ok``.
    """
    import argparse as _ap
    import os
    import tempfile
    from repro.serve.disagg import (DisaggregatedEngine, PrefillWorker,
                                    migrate_live)
    from repro.serve.engine import Request

    pargs = _ap.Namespace(**{**vars(args), "slots": 2, "num_blocks": 24,
                             "prefill_budget": None})
    cfg, control = build(pargs)
    rng = np.random.RandomState(args.seed)
    prompts = [rng.randint(2, cfg.vocab_size,
                           size=int(rng.randint(6, 20))) for _ in range(5)]

    def drive_pre(eng):
        for i, pr in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=pr.copy(),
                               max_new=args.max_new))
        for s in range(3):
            eng.step()
            if s == 1 and eng.running:
                eng.preempt_latest()

    drive_pre(control)
    control.run(600)
    want = {r.rid: list(r.generated) for r in control.done}

    _, src = build(pargs)
    drive_pre(src)
    path = os.path.join(tempfile.mkdtemp(prefix="bench_migrate_"),
                        "arena.npz")
    t0 = time.perf_counter()
    dst, sess = migrate_live(src, lambda: build(pargs)[1], path)
    mig_wall = time.perf_counter() - t0
    dst.run(600)
    rep = sess.migration_report()
    got = {r.rid: list(r.generated) for r in dst.done}
    migration = {
        **rep,
        "completed": len(dst.done),
        "token_identical": got == want,
        "migrate_wall_s": round(mig_wall, 3),
        "snapshot_bytes": os.path.getsize(path),
    }

    mono_want = {}
    _, mono = build(pargs)
    for i, pr in enumerate(prompts[:4]):
        mono.submit(Request(rid=i, prompt=pr.copy(), max_new=args.max_new))
    mono.run(600)
    mono_want = {r.rid: list(r.generated) for r in mono.done}
    pre = PrefillWorker(mono.model, mono.params, max_seq=args.max_seq,
                        num_blocks=24, eos_id=-1, prefill_budget=None)
    dis = DisaggregatedEngine(pre, build(pargs)[1])
    for i, pr in enumerate(prompts[:4]):
        dis.submit(Request(rid=i, prompt=pr.copy(), max_new=args.max_new))
    dis.run(600)
    disagg = {
        "handoffs": dis.handoffs,
        "handoff_bytes": dis.handoff_bytes,
        "completed": len(dis.done),
        "token_identical": ({r.rid: list(r.generated) for r in dis.done}
                            == mono_want),
    }
    ok = (migration["token_identical"]
          and rep["finalized"] and rep["rounds"] >= 2
          and 0 < rep["stop_copy_blocks"] < rep["blocks_per_round"][0]
          and rep["pause_steps"] == 1
          and disagg["token_identical"] and disagg["handoffs"] == 4)
    return {"migration": migration, "disagg": disagg, "ok": ok}


def workload(cfg, eng, args):
    """Mixed traffic: unique prompts + a shared-prefix cohort; the pool
    is sized by the caller to force queueing (and usually swapping)."""
    from repro.serve.engine import Request

    rng = np.random.RandomState(args.seed)
    cap = min(24, args.max_seq // 2)
    base = rng.randint(2, cfg.vocab_size, size=cap - 2)
    # consecutive cohort so its members are resident TOGETHER (fork
    # needs a live parent), like parallel sampling off one prompt
    cohort = range(1, 1 + max(2, args.requests // 3))
    rid = 0
    for i in range(args.requests):
        if i in cohort:                      # shared-prefix cohort
            extra = int(rng.randint(0, 4))
            pr = (np.concatenate([base, rng.randint(2, cfg.vocab_size,
                                                    size=extra)])
                  if extra else base.copy())
        else:
            pr = rng.randint(2, cfg.vocab_size,
                             size=int(rng.randint(4, cap)))
        eng.submit(Request(rid=rid, prompt=pr, max_new=args.max_new))
        rid += 1


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma_2b")
    ap.add_argument("--reduced", action="store_true", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config (reduced model, few requests)")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--num-blocks", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--watermark", type=int, default=1)

    def _budget(v):
        return ("auto" if v == "auto"
                else None if v in ("none", "None") else int(v))

    # the scripted workload defaults to None (unlimited, deterministic)
    # so its equivalence pins and the tokens/s floor stay schedule-
    # stable; the trace section exercises the adaptive "auto" default
    ap.add_argument("--prefill-budget", type=_budget, default=None,
                    help="int, 'auto', or 'none' (default: none)")
    ap.add_argument("--trace", default=None,
                    choices=("none", "static", "poisson", "bursty",
                             "heavytail", "prefixheavy"),
                    help="also run a live arrival trace through "
                         "Engine.serve and record per-tenant latency "
                         "(--smoke defaults to poisson)")
    ap.add_argument("--trace-tenants", type=int, default=2)
    ap.add_argument("--trace-gap", type=float, default=2.0,
                    help="mean inter-arrival gap in engine steps")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--baseline", default=None,
                    help="committed BENCH_serve.json to gate against: "
                         "exit 1 if tokens/s drops > --regress-frac")
    ap.add_argument("--regress-frac", type=float, default=0.2)
    args = ap.parse_args(argv)
    if args.smoke:
        args.reduced = True
        args.requests = min(args.requests, 9)
        args.slots = min(args.slots, 3)
        if args.trace is None:
            args.trace = "poisson"
    if args.reduced is None:
        args.reduced = True
    if args.trace in (None, "none"):
        args.trace = None

    if args.smoke:
        # warm the shared jit cache (one untimed scripted run) so the
        # multiqueue-vs-drain mode comparison below measures scheduling
        # overhead, not whichever run happens to pay first-trace
        # compilation
        wcfg, weng = build(args)
        drive(wcfg, weng, args)
    cfg, eng = build(args)
    dt = drive(cfg, eng, args)
    eng2 = dt2 = None
    if args.smoke:
        # the drain() fallback for the equivalence pins below, then an
        # order-balanced second timed round per mode (the second run of
        # any pair is always warmer -- alternate so neither mode owns
        # the warm seat, and report each mode's best)
        cfg2, eng2 = build(args, overlap=False)
        dt2 = drive(cfg2, eng2, args)
        for ov in (False, True, True, False):   # best-of-3 per mode
            c, e = build(args, overlap=ov)
            d = drive(c, e, args)
            if ov:
                dt = min(dt, d)
            else:
                dt2 = min(dt2, d)

    st = eng.stats
    swp = eng.store.stats
    blocks_swapped = sum(n for _, n, _ in swp.out_log)
    per_block = eng.cache.config.swap_nbytes_per_block()
    report = {
        "arch": args.arch,
        "requests": args.requests,
        "completed": len(eng.done),
        "steps": eng.steps,
        "wall_s": round(dt, 3),
        "decode_tokens": st["decode_tokens"],
        "prefill_tokens": st["prefill_tokens"],
        "prefill_tokens_saved": st["prefill_tokens_saved"],
        "tokens_per_s": round(st["decode_tokens"] / max(dt, 1e-9), 2),
        "swap_out_bytes": st["swap_out_bytes"],
        "swap_in_bytes": st["swap_in_bytes"],
        "swap_bytes_per_step": round(
            (st["swap_out_bytes"] + st["swap_in_bytes"])
            / max(eng.steps, 1), 1),
        "blocks_swapped_out": blocks_swapped,
        "swap_nbytes_per_block": per_block,
        "swap_bytes_proportional": (
            st["swap_out_bytes"] == blocks_swapped * per_block),
        "preemptions": st["preemptions"],
        "prefix_hits": st["prefix_hits"],
        "prefix_share_hit_rate": round(
            st["prefix_hits"] / max(args.requests, 1), 3),
        "prefetches": st["prefetches"],
        "prefetch_hits": st["prefetch_hits"],
        "prefetch_cancels": st["prefetch_cancels"],
        "prefetch_hit_rate": round(st["prefetch_hit_rate"], 3),
        "cow_copies": st["cow_copies"],
        "compactions": st["compactions"],
        "blocks_compacted": st["blocks_compacted"],
        "pool_utilization_final": round(st["pool_utilization"], 3),
        "watermark_effective": st["watermark_effective"],
        "arena": eng.arena_stats().to_dict(),
        "transfers": st["transfers"],
        "overlap_transfers": True,
        "resident_tables": st["resident_tables"],
        "host_uploads": st["host_uploads"],
        "host_uploads_per_step": round(st["host_uploads_per_step"], 3),
        "table_sync_bytes": st["table_sync_bytes"],
        "table_rows_updated": st["table_rows_updated"],
        "phase_time_s": {k: round(v, 4)
                         for k, v in st["phase_time_s"].items()},
        "all_ok": (len(eng.done) == args.requests
                   and st["prefix_hits"] > 0
                   and st["swap_out_bytes"]
                   == blocks_swapped * per_block),
    }
    transfers_doc = {
        **report["transfers"],
        # per-engine queue-depth high-water marks (the multi-queue
        # refactor's headline observability) and the prefetch outcome
        "queue_depths": report["transfers"]["max_pending"],
        "prefetch_hit_rate": report["prefetch_hit_rate"],
        "prefetch_hits": report["prefetch_hits"],
        "modes": {"multiqueue+prefetch": report["tokens_per_s"]},
    }
    if args.smoke:
        # the per-engine queues + speculation may only RESCHEDULE
        # traffic, never change a decision: the single-queue drain()
        # fallback must move byte-identical demand swap volume and
        # decode identical PER-REQUEST tokens.  (Step counts are no
        # longer pinned -- the adaptive prefill budget is free to
        # re-time admissions without changing what anyone decodes.)
        st2 = eng2.stats
        report["sync_swap_bytes_per_step"] = round(
            (st2["swap_out_bytes"] + st2["swap_in_bytes"])
            / max(eng2.steps, 1), 1)
        transfers_doc["modes"]["single-queue-drain"] = round(
            st2["decode_tokens"] / max(dt2, 1e-9), 2)
        report["overlap_equivalent"] = (
            st2["swap_out_bytes"] == st["swap_out_bytes"]
            and st2["swap_in_bytes"] == st["swap_in_bytes"]
            and {r.rid: list(r.generated) for r in eng2.done}
            == {r.rid: list(r.generated) for r in eng.done})
        # CI gate: the scripted forced-preemption probe must serve at
        # least one LIFO resume from a COMPLETED speculative prefetch.
        # (The probe's hit rate stays under its own key -- it must NOT
        # overwrite the workload-level rate: the old snapshots reported
        # a vacuous 1.0 next to prefetch_enqueued == 0.)
        probe = prefetch_probe(args)
        report["prefetch_probe"] = probe
        transfers_doc["prefetch_probe"] = probe
        report["all_ok"] = (report["all_ok"]
                            and report["overlap_equivalent"]
                            and probe["completed"] == 4
                            and probe["prefetch_hits"] > 0)
        # CI gate: the prefix-heavy trace must decode token-identical
        # with suffix-only prefill on vs full recompute, and the suffix
        # path must actually skip work
        sp = suffix_probe(args)
        report["suffix_prefill_probe"] = sp
        transfers_doc["modes"]["prefixheavy+suffix"] = \
            sp["suffix"]["tokens_per_s"]
        transfers_doc["modes"]["prefixheavy+full-recompute"] = \
            sp["full-recompute"]["tokens_per_s"]
        transfers_doc["prefill_tokens_saved"] = \
            sp["suffix"]["prefill_tokens_saved"]
        report["all_ok"] = (report["all_ok"]
                            and sp["token_identical"]
                            and sp["suffix"]["prefill_tokens_saved"] > 0)
        # CI gate: the resident decode path (device-persistent tables,
        # delta sync, fused donated step tail) must decode token-
        # identical to the eager full-rebuild fallback across the
        # forced-preemption workload, while performing strictly fewer
        # host uploads per step than eager's fixed two
        dp = decode_path_probe(args)
        report["decode_path"] = dp
        transfers_doc["modes"]["decode+resident"] = \
            dp["resident"]["tokens_per_s"]
        transfers_doc["modes"]["decode+eager-rebuild"] = \
            dp["eager"]["tokens_per_s"]
        report["all_ok"] = report["all_ok"] and dp["ok"]
        # CI gate: the architecture registry must serve all three cache
        # disciplines from one shared Arena token-identically to each
        # family's standalone run, with a preemption round-trip through
        # every pool class and a quiescent arena at drain
        mx = mixed_arch_probe(args)
        report["mixed_arch"] = mx
        report["all_ok"] = report["all_ok"] and mx["ok"]
        # CI gate: mid-decode live migration must resume token-identical
        # to an unmigrated control with a stop-and-copy tail strictly
        # smaller than the first pre-copy round, and disaggregated
        # prefill must match the monolithic engine; the section also
        # lands standalone in BENCH_migrate.json
        mg = migrate_probe(args)
        report["migrate"] = mg
        report["all_ok"] = report["all_ok"] and mg["ok"]
        with open(OUT_MIGRATE, "w") as f:
            json.dump(mg, f, indent=2)
    if args.trace:
        # the request plane: live arrivals through Engine.serve, with
        # per-tenant latency percentiles and the TTFT histogram
        tp = trace_probe(args)
        report["arrival_trace"] = tp["arrival_trace"]
        report["tenant_latency"] = tp["tenant_latency"]
        report["latency_histogram"] = tp["latency_histogram"]
        report["trace_tokens_per_s"] = tp["tokens_per_s"]
        report["trace_steps"] = tp["steps"]
        transfers_doc["modes"]["arrival-trace"] = tp["tokens_per_s"]
        report["all_ok"] = (report["all_ok"]
                            and tp["completed"]
                            == tp["arrival_trace"]["requests"]
                            and bool(tp["tenant_latency"]))
    with open(OUT_JSON, "w") as f:
        json.dump(report, f, indent=2)
    with open(OUT_TRANSFERS, "w") as f:
        json.dump(transfers_doc, f, indent=2)
    probe_hits = report.get("prefetch_probe", {}).get("prefetch_hits", "-")
    trace_info = (f"{args.trace}:{report['trace_tokens_per_s']}tok/s"
                  if args.trace else "-")
    print(f"bench_serve,{dt * 1e6:.0f},tok_s={report['tokens_per_s']},"
          f"hit_rate={report['prefix_share_hit_rate']},"
          f"swapB_step={report['swap_bytes_per_step']},"
          f"overlapped={report['transfers']['overlapped']},"
          f"probe_prefetch_hits={probe_hits},"
          f"trace={trace_info},"
          f"prefill_saved={report['prefill_tokens_saved']},"
          f"mixed_arch_ok={report.get('mixed_arch', {}).get('ok', '-')},"
          f"migrate_ok={report.get('migrate', {}).get('ok', '-')},"
          f"decode_path_ok={report.get('decode_path', {}).get('ok', '-')},"
          f"all_ok={report['all_ok']},json={OUT_JSON}")
    if not report["all_ok"]:
        raise SystemExit(1)
    if args.baseline:
        try:
            with open(args.baseline) as f:
                base = json.load(f)
        except (FileNotFoundError, json.JSONDecodeError) as e:
            print(f"bench_serve: no usable baseline at {args.baseline} "
                  f"({e}); skipping regression gate")
        else:
            old = float(base.get("tokens_per_s") or 0.0)
            floor = (1.0 - args.regress_frac) * old
            if old and report["tokens_per_s"] < floor:
                raise SystemExit(
                    f"tokens/s regression: {report['tokens_per_s']} < "
                    f"{floor:.2f} ({(1 - args.regress_frac) * 100:.0f}% of "
                    f"baseline {old})")
            print(f"bench_serve: tokens/s {report['tokens_per_s']} vs "
                  f"baseline {old} (floor {floor:.2f}) -- ok")
    return report


if __name__ == "__main__":
    main()
