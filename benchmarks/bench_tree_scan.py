"""Paper Table 2: linear & strided scans, tree vs contiguous array,
naive vs iterator disciplines, across array sizes.

CPU-host reproduction of the paper's microbenchmark (their 'physical
memory' is simulated here by the absence of any translation layer in
JAX's flat buffers -- what we measure is exactly the SOFTWARE overhead
of the tree discipline, the quantity the paper isolates in Table 2).
Sizes are scaled to container memory; depths 1-3 are all exercised.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core.treearray import TreeArray

LEAF = 8192          # 32 KB of f32 -- the paper's block
FANOUT = 256         # keeps depth-3 reachable at bench sizes

# (label, n elements)  4 KB .. 256 MB
SIZES = [("4KB", 1 << 10), ("4MB", 1 << 20), ("64MB", 1 << 24),
         ("256MB", 1 << 26)]


def dense_linear_sum(x):
    return jnp.sum(x)


def dense_strided_sum(x, stride=1024):
    return jnp.sum(x[::stride])


def run() -> None:
    rng = np.random.RandomState(0)
    for label, n in SIZES:
        x = rng.randn(n).astype(np.float32)
        xd = jnp.asarray(x)
        t = TreeArray.from_dense(x, leaf_size=LEAF, fanout=FANOUT,
                                 shuffle_seed=1)

        f_dense = jax.jit(dense_linear_sum)
        us_dense = time_fn(f_dense, xd)
        emit(f"linear_scan_dense_{label}", us_dense, f"depth=0,n={n}")

        f_iter = jax.jit(lambda tt: tt.scan_sum_iter())
        us_iter = time_fn(f_iter, t)
        emit(f"linear_scan_tree_iter_{label}", us_iter,
             f"depth={t.depth},ratio={us_iter / us_dense:.3f}")

        if n <= (1 << 20):   # naive per-element walk is O(n) sequential
            f_naive = jax.jit(lambda tt: tt.scan_sum_naive())
            us_naive = time_fn(f_naive, t, iters=3, warmup=1)
            emit(f"linear_scan_tree_naive_{label}", us_naive,
                 f"ratio={us_naive / us_dense:.3f}")

        # strided: every 1024th element (paper: 4 KB apart)
        idx = jnp.arange(0, n, 1024)
        f_sd = jax.jit(dense_strided_sum)
        us_sd = time_fn(f_sd, xd)
        emit(f"strided_scan_dense_{label}", us_sd, "")
        f_st = jax.jit(lambda tt, ii: jnp.sum(tt.get_naive(ii)))
        us_st = time_fn(f_st, t, idx)
        emit(f"strided_scan_tree_{label}", us_st,
             f"ratio={us_st / us_sd:.3f}")


if __name__ == "__main__":
    run()
