"""Paper Fig. 4: GUPS (random update) and a pointer-chase workload
(red-black-tree analogue), tree vs contiguous.

GUPS: scatter-add at pseudorandom indices.  The tree pays depth-1
indirection per access; the paper's point is that this software cost is
small and flat while hardware translation costs grow with footprint.
Pointer-chase: a linked permutation walked sequentially -- identical
data structure in both layouts (the paper used the same red-black tree
on both systems), so the delta isolates the addressing substrate.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core.treearray import TreeArray

SIZES = [("4MB", 1 << 20), ("64MB", 1 << 24), ("256MB", 1 << 26)]
N_UPD = 1 << 16


def run() -> None:
    rng = np.random.RandomState(0)
    for label, n in SIZES:
        x = np.zeros(n, np.float32)
        idx = jnp.asarray(rng.randint(0, n, N_UPD))
        upd = jnp.asarray(rng.randn(N_UPD).astype(np.float32))

        xd = jnp.asarray(x)
        f_dense = jax.jit(lambda a, i, u: a.at[i].add(u))
        us_dense = time_fn(f_dense, xd, idx, upd)
        emit(f"gups_dense_{label}", us_dense, f"n={n}")

        t = TreeArray.from_dense(x, leaf_size=8192, fanout=256,
                                 shuffle_seed=1)
        f_tree = jax.jit(lambda tt, i, u: tt.add(i, u))
        us_tree = time_fn(f_tree, t, idx, upd)
        emit(f"gups_tree_{label}", us_tree,
             f"depth={t.depth},ratio={us_tree / us_dense:.3f}")

    # pointer chase: permutation cycle walk, same structure both layouts
    n = 1 << 20
    perm = rng.permutation(n).astype(np.int32)
    nxt_dense = jnp.asarray(perm)
    t_nxt = TreeArray.from_dense(perm.astype(np.float32), leaf_size=8192,
                                 fanout=256, shuffle_seed=2)

    def chase_dense(nxt, steps=4096):
        def body(i, _):
            return nxt[i], None
        last, _ = jax.lax.scan(body, jnp.asarray(0, jnp.int32), None,
                               length=steps)
        return last

    def chase_tree(tt, steps=4096):
        def body(i, _):
            return tt.get_naive(i).astype(jnp.int32), None
        last, _ = jax.lax.scan(body, jnp.asarray(0, jnp.int32), None,
                               length=steps)
        return last

    us_d = time_fn(jax.jit(chase_dense), nxt_dense, iters=5)
    emit("chase_dense_4MB", us_d, "")
    us_t = time_fn(jax.jit(chase_tree), t_nxt, iters=5)
    emit("chase_tree_4MB", us_t, f"ratio={us_t / us_d:.3f}")


if __name__ == "__main__":
    run()
