"""Benchmark harness utilities: timed jitted calls, CSV emission."""

from __future__ import annotations

import time
from typing import Callable

import jax


def time_fn(fn: Callable, *args, iters: int = 10, warmup: int = 3) -> float:
    """Median wall time in microseconds of a jitted callable."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.2f},{derived}")
