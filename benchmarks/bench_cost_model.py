"""Cost-model validation: predicted vs analytically-known bytes on the
paper's two indirection primitives (paged-KV access, tree gather).

For each workload we compile the pure-jnp reference (the thing the
dry-run pipeline actually lowers), run ``repro.cost`` over the optimized
HLO, and compare the predicted byte bill against the analytic ground
truth (bytes the operation must move given its shapes).  XLA's own
``cost_analysis()`` "bytes accessed" is recorded alongside -- it is the
number the subsystem replaces (full-operand billing, loop bodies counted
once).

Emits CSV rows like every other bench AND writes ``BENCH_cost_model.json``
(consumed by run.py / CI) with per-case predicted / analytic / xla bytes
and the predicted:analytic ratio.  A case FAILS validation when the
ratio leaves [0.5, 4.0] -- i.e. the instrument drifts by more than the
fusion-materialization slack it is allowed.
"""

from __future__ import annotations

import json
from typing import Dict, List

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro import cost
from repro.kernels import ref

OUT_JSON = "BENCH_cost_model.json"
RATIO_LO, RATIO_HI = 0.5, 4.0


def _time_donated(jfn, specs, iters: int = 10, warmup: int = 3) -> float:
    """Median us/call with fresh (donatable) args created outside the
    timed region each call -- a donated program invalidates its inputs,
    so the plain time_fn re-use pattern would die or silently re-copy."""
    import time as _time

    def mk():
        return [jnp.zeros(s.shape, s.dtype) for s in specs]

    for _ in range(warmup):
        out = jfn(*mk())
    jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        args = mk()
        jax.block_until_ready(args)
        t0 = _time.perf_counter()
        out = jfn(*args)
        jax.block_until_ready(out)
        times.append(_time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def _case(name: str, fn, analytic_bytes: float, *specs,
          donate: tuple = ()) -> Dict:
    jfn = jax.jit(fn, donate_argnums=donate)
    compiled = jfn.lower(*specs).compile()
    predicted = cost.analyze_compiled(compiled)
    xla_raw = cost.xla_cost_analysis(compiled)
    # time the SAME donated program the byte bill was derived from
    if donate:
        us = _time_donated(jfn, specs)
    else:
        args = [jnp.zeros(s.shape, s.dtype) for s in specs]
        us = time_fn(jfn, *args)
    ratio = predicted.bytes / max(analytic_bytes, 1.0)
    row = {
        "name": name,
        "predicted_bytes": predicted.bytes,
        "analytic_bytes": analytic_bytes,
        "xla_bytes_accessed": float(xla_raw.get("bytes accessed", 0.0)),
        "ratio_predicted_to_analytic": ratio,
        "by_op": {k: v for k, v in sorted(predicted.by_op.items(),
                                          key=lambda kv: -kv[1])},
        "us_per_call": us,
        "ok": RATIO_LO <= ratio <= RATIO_HI,
    }
    emit(f"cost_model_{name}", us,
         f"pred={predicted.bytes:.0f},analytic={analytic_bytes:.0f},"
         f"ratio={ratio:.2f},ok={row['ok']}")
    return row


def run() -> None:
    rows: List[Dict] = []

    # ---- tree gather: pool indirection (paper Table 2 discipline) ----
    NB, LEAF = 512, 8192          # 16 MB pool of 32 KB blocks
    NL = 64                       # gather 64 logical blocks (2 MB)
    touched = NL * LEAF * 4
    rows.append(_case(
        "tree_gather",
        ref.tree_gather_ref,
        # read gathered leaves + write result + read table
        2 * touched + NL * 4,
        jax.ShapeDtypeStruct((NB, LEAF), jnp.float32),
        jax.ShapeDtypeStruct((NL,), jnp.int32)))

    # ---- tree gather rows: paged embedding lookup ----
    RPB, W, NROW = 64, 256, 128
    rows.append(_case(
        "tree_gather_rows",
        lambda pool, ids, tbl: ref.tree_gather_rows_ref(pool, ids, tbl, RPB),
        2 * NROW * W * 4 + NROW * 4 + NROW * 4,
        jax.ShapeDtypeStruct((NB // 8, RPB, W), jnp.float32),
        jax.ShapeDtypeStruct((NROW,), jnp.int32),
        jax.ShapeDtypeStruct((NB // 8,), jnp.int32)))

    # ---- paged KV decode read: block-table gather + attention-sized
    # reduce (the serving-path working set) ----
    B, H, D, BT, S = 8, 4, 128, 16, 512
    MB = S // BT
    NBLK = 4 * B * MB             # pool 4x the live working set

    def paged_read(pool, tbl):
        pages = pool[jnp.maximum(tbl, 0)]          # (B, MB, BT, H, D)
        return pages.sum(axis=(1, 2))

    gathered = B * MB * BT * H * D * 4
    rows.append(_case(
        "paged_kv_read",
        paged_read,
        # gather read + materialized write + reduce re-read + output
        3 * gathered + B * H * D * 4,
        jax.ShapeDtypeStruct((NBLK, BT, H, D), jnp.float32),
        jax.ShapeDtypeStruct((B, MB), jnp.int32)))

    # ---- paged KV token write: the in-place block update whose
    # overcounting (full-pool billing) this subsystem exists to fix ----
    def paged_write(pool, blk, off, kv):
        flat = pool.reshape(NBLK * BT, H, D)
        flat = flat.at[blk * BT + off].set(kv)
        return flat.reshape(NBLK, BT, H, D)

    token = B * H * D * 4
    rows.append(_case(
        "paged_kv_token_write",
        paged_write,
        2 * token,                 # read token + write slice, NOT pool
        jax.ShapeDtypeStruct((NBLK, BT, H, D), jnp.float32),
        jax.ShapeDtypeStruct((B,), jnp.int32),
        jax.ShapeDtypeStruct((B,), jnp.int32),
        jax.ShapeDtypeStruct((B, H, D), jnp.float32),
        donate=(0,)))

    report = {
        "bench": "cost_model",
        "cases": rows,
        "all_ok": all(r["ok"] for r in rows),
    }
    with open(OUT_JSON, "w") as f:
        json.dump(report, f, indent=2)
    emit("cost_model_validation", 0.0,
         f"all_ok={report['all_ok']},json={OUT_JSON}")
    if not report["all_ok"]:
        bad = [r["name"] for r in rows if not r["ok"]]
        raise AssertionError(f"cost model drifted on: {bad}")


if __name__ == "__main__":
    run()
