"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  These are CPU-host
measurements of the SOFTWARE disciplines the paper isolates (tree
indirection, split-stack checks, paged vs contiguous serving); the TPU
roofline numbers live in the dry-run pipeline (EXPERIMENTS.md).

    PYTHONPATH=src python -m benchmarks.run [--only tree_scan,gups,...]
"""

import argparse
import sys
import traceback

MODULES = ["tree_scan", "gups", "stack", "end2end", "cost_model"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    mods = args.only.split(",") if args.only else MODULES
    print("name,us_per_call,derived")
    failed = []
    for m in mods:
        try:
            mod = __import__(f"benchmarks.bench_{m}", fromlist=["run"])
            mod.run()
        except Exception as e:
            failed.append(m)
            print(f"{m},ERROR,{type(e).__name__}: {e}", file=sys.stderr)
            traceback.print_exc()
    sys.exit(1 if failed else 0)


if __name__ == '__main__':
    main()
