"""Elastic restore: train on an 8-device mesh, crash, resume on 4 devices.

Block-based checkpoints make recovery onto a DIFFERENT device count a
metadata remap (DESIGN.md §3): this script spawns the two phases as
subprocesses with different forced host-device counts.

    PYTHONPATH=src python examples/elastic_resume.py
"""

import os
import subprocess
import sys
import tempfile
import textwrap

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

PHASE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ndev}"
import sys
sys.path.insert(0, {src!r})
from repro.launch.train import main
out = main(["--arch", "gemma_2b", "--reduced", "--steps", "{steps}",
            "--batch", "4", "--seq", "32", "--lr", "1e-3",
            "--ckpt-dir", {ckpt!r}, "--ckpt-every", "10",
            "--model-parallel", "{mp}"])
print("PHASE_DONE", out["losses"][-1])
"""


def run_phase(ndev, mp, steps, ckpt):
    prog = PHASE.format(ndev=ndev, mp=mp, steps=steps, ckpt=ckpt,
                        src=os.path.join(ROOT, "src"))
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, cwd=ROOT)
    if r.returncode != 0:
        print(r.stderr[-2000:])
        raise SystemExit(1)
    for line in r.stdout.splitlines():
        if line.startswith(("[", "PHASE_DONE", "final")):
            print("   ", line)


def main():
    ckpt = tempfile.mkdtemp(prefix="elastic_")
    print("phase 1: 8 devices (data=4, model=2), 20 steps")
    run_phase(8, 2, 20, ckpt)
    print("phase 2: 'cluster shrank' -> 4 devices (data=2, model=2), "
          "resume to 40")
    run_phase(4, 2, 40, ckpt)
    print("elastic resume complete: same checkpoints, different mesh.")


if __name__ == "__main__":
    main()
