"""Serving example: the layered stack (scheduler / swap store / engine)
under admission pressure, preemption-by-swap and COW prefix sharing.

    PYTHONPATH=src python examples/serve_paged.py
"""

import numpy as np
import jax

from repro.configs.base import get_config
from repro.models.api import build_model
from repro.serve.engine import Engine, Request


def main():
    cfg = get_config("gemma_2b").reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))

    # a pool deliberately too small for all requests at once: the
    # scheduler queues, admits FCFS by free-block count (1 block kept as
    # growth headroom), and the engine swaps blocks under pressure
    eng = Engine(model, params, slots=2, max_seq=64, num_blocks=20,
                 eos_id=-1, watermark=1)
    rng = np.random.RandomState(0)
    base = rng.randint(2, cfg.vocab_size, size=12)
    for i in range(6):
        if i in (2, 3):            # admitted together -> COW prefix fork
            pr = base.copy()
        else:
            plen = int(rng.randint(4, 12))
            pr = rng.randint(2, cfg.vocab_size, size=plen)
        eng.submit(Request(rid=i, prompt=pr, max_new=8))
    print(f"submitted 6 requests into a {eng.mgr.allocator.num_blocks}"
          f"-block pool, 2 slots (requests 2 and 3 share one prompt)")

    while eng.sched.has_work or eng.running:
        eng.step()
        if eng.steps % 4 == 0:
            print(f"  step {eng.steps:3d}: running={len(eng.running)} "
                  f"queued={len(eng.queue)} done={len(eng.done)} "
                  f"pool={eng.mgr.utilization:.0%}")
        if eng.steps > 200:
            break

    for req in sorted(eng.done, key=lambda r: r.rid):
        print(f"request {req.rid}: prompt[{len(req.prompt)}] -> "
              f"{req.generated}")
    st = eng.stats
    print(f"prefix-share hits: {st['prefix_hits']}, COW copies: "
          f"{st['cow_copies']}, swap bytes out/in: "
          f"{st['swap_out_bytes']}/{st['swap_in_bytes']}")
    assert len(eng.done) == 6
    print("all requests completed; peak pool utilization bounded by the "
          "block allocator (no overcommit).")


if __name__ == "__main__":
    main()
