"""Serving example: continuous batching over the paged KV pool,
including admission pressure and preemption-by-swap.

    PYTHONPATH=src python examples/serve_paged.py
"""

import numpy as np
import jax

from repro.configs.base import get_config
from repro.models.api import build_model
from repro.serve.engine import Engine, Request


def main():
    cfg = get_config("gemma_2b").reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))

    # a pool deliberately too small for all requests at once: the engine
    # queues, admits by free-block count, and swaps under pressure
    eng = Engine(model, params, slots=2, max_seq=64, num_blocks=20,
                 eos_id=-1)
    rng = np.random.RandomState(0)
    for i in range(6):
        plen = int(rng.randint(4, 12))
        eng.submit(Request(rid=i, prompt=rng.randint(2, cfg.vocab_size,
                                                     size=plen),
                           max_new=8))
    print(f"submitted 6 requests into a {eng.mgr.allocator.num_blocks}"
          f"-block pool, 2 slots")

    while eng.queue or eng.running or len(eng.preempted):
        eng.step()
        if eng.steps % 4 == 0:
            print(f"  step {eng.steps:3d}: running={len(eng.running)} "
                  f"queued={len(eng.queue)} done={len(eng.done)} "
                  f"pool={eng.mgr.utilization:.0%}")
        if eng.steps > 200:
            break

    for req in sorted(eng.done, key=lambda r: r.rid):
        print(f"request {req.rid}: prompt[{len(req.prompt)}] -> "
              f"{req.generated}")
    assert len(eng.done) == 6
    print("all requests completed; peak pool utilization bounded by the "
          "block allocator (no overcommit).")


if __name__ == "__main__":
    main()
