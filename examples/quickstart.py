"""Quickstart: the paper's primitives in 60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import BlockStack, TreeArray
from repro.kernels import ops
from repro.mem import Arena

# -- 1. arrays-as-trees: a "large" array in fixed 32 KB blocks ------------
x = np.arange(100_000, dtype=np.float32)
tree = TreeArray.from_dense(x, leaf_size=8192, fanout=256, shuffle_seed=7)
print(f"TreeArray: n={tree.length} depth={tree.depth} "
      f"leaves={tree.num_logical_leaves} "
      f"indirection_overhead={tree.overhead_bytes}B")

# naive access (full tree walk per element) vs iterator discipline
idx = jnp.asarray([0, 4096, 99_999])
print("random access:", tree.get_naive(idx))
print("linear-scan sum (iterator):", float(tree.scan_sum_iter()),
      "== dense:", float(x.sum()))

# -- 2. the same walk as a TPU kernel (scalar-prefetched block table) -----
table = tree.leaf_table()
out = ops.tree_gather(tree.leaves, table, interpret=True)
assert np.allclose(np.asarray(out).reshape(-1)[: len(x)], x)
print("Pallas tree_gather kernel matches (interpret mode)")

# -- 3. many tenants, one arena (the unified software address space) -----
arena = Arena()
arena.register_class("main", num_blocks=64, block_shape=(8192,),
                     dtype=np.float32)
t1 = TreeArray.from_dense(np.ones(20_000, np.float32), leaf_size=8192,
                          arena=arena, pool_class="main", owner="t1")
t2 = TreeArray.from_dense(np.full(5_000, 2.0, np.float32), leaf_size=8192,
                          arena=arena, pool_class="main", owner="t2")
print(f"arena: {arena.num_used('main')}/{arena.num_blocks('main')} "
      f"blocks used by 2 tenants")

# -- 4. split stack ------------------------------------------------------
stack = BlockStack(block_size=4096, arena=arena, pool_class="main",
                   owner="stack")
for i in range(10_000):
    stack.push(i)
print(f"BlockStack: {len(stack)} items in {stack.num_blocks} linked blocks "
      f"(arena now {arena.num_used('main')}/{arena.num_blocks('main')}; "
      f"by owner: {arena.stats()['main'].blocks_by_owner})")
while len(stack):
    stack.pop()
print(f"drained; arena back to {arena.num_used('main')} data blocks")

# -- 5. paged attention over a block-table-addressed KV cache ------------
rng = np.random.RandomState(0)
B, KVH, G, HD, BT, MB = 2, 2, 4, 64, 16, 4
q = jnp.asarray(rng.randn(B, KVH, G, HD).astype(np.float32))
k_pool = jnp.asarray(rng.randn(B * MB, BT, KVH, HD).astype(np.float32))
v_pool = jnp.asarray(rng.randn(B * MB, BT, KVH, HD).astype(np.float32))
tables = jnp.asarray(rng.permutation(B * MB).reshape(B, MB).astype(np.int32))
lens = jnp.asarray(np.array([50, 33], np.int32))
o = ops.paged_attention(q, k_pool, v_pool, tables, lens, interpret=True)
o_ref = ops.paged_attention_ref(q, k_pool, v_pool, tables, lens)
assert np.allclose(np.asarray(o), np.asarray(o_ref), atol=1e-4)
print("paged_attention kernel == reference; done.")
