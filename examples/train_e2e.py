"""End-to-end training driver: a ~100M-param gemma-style model on the
synthetic pipeline for a few hundred steps, with block-based checkpoints
and automatic resume.

    PYTHONPATH=src python examples/train_e2e.py [--steps 300] [--full]

Default runs a width-reduced model sized for CPU wall-clock; --full uses
the real ~100M config (slower).  Kill it mid-run and re-run: it resumes
from the last checkpoint and reproduces the uninterrupted trajectory.
"""

import argparse
import dataclasses

from repro.configs.base import ModelConfig
from repro.launch.train import main as train_main
import repro.configs.base as base


# a ~100M-param dense LM (gemma-flavored): 12L, d=768, 12H, ff=3072
CONFIG_100M = ModelConfig(
    name="demo-100m", family="dense", num_layers=12, d_model=768,
    num_heads=12, kv_heads=4, head_dim=64, d_ff=3072, vocab_size=32768,
    mlp="geglu", rope_theta=10000.0, tie_embeddings=True, dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full", action="store_true",
                    help="real 100M config (CPU-slow); default is reduced")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
    args = ap.parse_args()

    # register the demo config so --arch resolves
    import sys
    import types
    mod = types.ModuleType("repro.configs.demo_100m")
    mod.CONFIG = CONFIG_100M if args.full else CONFIG_100M and \
        dataclasses.replace(CONFIG_100M, num_layers=4, d_model=256,
                            d_ff=1024, vocab_size=4096, num_heads=4,
                            kv_heads=2)
    sys.modules["repro.configs.demo_100m"] = mod

    out = train_main([
        "--arch", "demo_100m", "--steps", str(args.steps),
        "--batch", "8", "--seq", "256", "--lr", "1e-3",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50",
    ])
    losses = out["losses"]
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} over "
          f"{len(losses)} steps")


if __name__ == "__main__":
    main()
